//! XLA runtime integration: the AOT-compiled JAX/Pallas artifacts must
//! agree with the native Rust implementations — this is the cross-layer
//! correctness contract of the three-layer architecture.
//!
//! These tests require the `xla-runtime` feature (with the vendored
//! `xla` crate) and `make artifacts` to have run (the Makefile's `test`
//! target guarantees the order). The hermetic default build compiles
//! this file to nothing.
#![cfg(feature = "xla-runtime")]

use magbdp::model::{ColorIndex, InitiatorMatrix, MagmParams};
use magbdp::runtime::{XlaAccept, XlaRuntime};
use magbdp::sampler::bdp::BallBatch;
use magbdp::sampler::magm_bdp::{AcceptBackend, MagmBdpSampler, NativeAccept};
use magbdp::sampler::proposal::Component;
use magbdp::util::rng::{Rng, SeedableRng, Xoshiro256pp};

fn runtime() -> &'static XlaRuntime {
    XlaRuntime::global().expect("XLA runtime (did `make artifacts` run?)")
}

#[test]
fn edge_stats_parity_across_parameters() {
    let rt = runtime();
    for theta in [InitiatorMatrix::THETA1, InitiatorMatrix::THETA2] {
        for (d, mu) in [(1usize, 0.5), (6, 0.3), (14, 0.7), (20, 0.45)] {
            let params = MagmParams::replicated(theta, d, mu, 1u64 << d.min(20));
            let native = params.edge_stats();
            let xla = rt.edge_stats(&params).expect("edge_stats artifact");
            let pairs = [
                (xla[0], native.e_k),
                (xla[1], native.e_m),
                (xla[2], native.e_km),
                (xla[3], native.e_mk),
            ];
            for (i, (got, want)) in pairs.iter().enumerate() {
                let rel = (got - want).abs() / want.abs().max(1e-12);
                // f32 product chains over up to 20 levels: allow 1e-3.
                assert!(rel < 1e-3, "theta={theta} d={d} mu={mu} stat#{i}: {got} vs {want}");
            }
        }
    }
}

#[test]
fn kron_batch_parity_random_pairs() {
    let rt = runtime();
    let params = MagmParams::replicated(InitiatorMatrix::THETA2, 16, 0.5, 1 << 16);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let cs: Vec<u64> = (0..1024).map(|_| rng.next_below(1 << 16)).collect();
    let ct: Vec<u64> = (0..1024).map(|_| rng.next_below(1 << 16)).collect();
    let got = rt.kron_batch(params.stack(), &cs, &ct).expect("kron_batch");
    for ((&c, &cp), g) in cs.iter().zip(&ct).zip(&got) {
        let want = params.stack().kron_entry(c, cp);
        let rel = (g - want).abs() / want.abs().max(1e-30);
        assert!(rel < 1e-4, "({c},{cp}): {g} vs {want}");
    }
}

#[test]
fn gamma_tile_parity_at_offsets() {
    let rt = runtime();
    let params = MagmParams::replicated(InitiatorMatrix::FIG1, 10, 0.5, 1 << 10);
    for (r0, c0) in [(0u32, 0u32), (64, 128), (960, 960)] {
        let tile = rt.gamma_tile(params.stack(), r0, c0).expect("gamma_tile");
        assert_eq!(tile.len(), 64);
        for (i, row) in tile.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                let want = params
                    .stack()
                    .kron_entry(r0 as u64 + i as u64, c0 as u64 + j as u64);
                let rel = (v - want).abs() / want.abs().max(1e-30);
                assert!(rel < 1e-4, "offset ({r0},{c0}) cell ({i},{j})");
            }
        }
    }
}

#[test]
fn accept_backend_parity_native_vs_xla() {
    // The heart of the 3-layer contract: the Pallas kernel's first-
    // principles Λ/Λ' must equal the native factorised lookup for every
    // component, on proposals actually drawn from the component BDPs.
    let params = MagmParams::replicated(InitiatorMatrix::THETA1, 12, 0.35, 1 << 12);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let assignment = params.sample_attributes(&mut rng);
    let sampler = MagmBdpSampler::new(&params, &assignment);
    let index = ColorIndex::build(&params, &assignment);
    let mut xla = XlaAccept::new(&params, &index).expect("XlaAccept");
    let mut native = NativeAccept;

    for comp in Component::ALL {
        let bdp = sampler.proposal().bdp(comp);
        let mut balls = BallBatch::with_capacity(2000);
        for _ in 0..2000 {
            let (c, cp) = bdp.drop_ball(&mut rng);
            balls.push(c, cp);
        }
        let mut probs_native = Vec::new();
        let mut probs_xla = Vec::new();
        native.accept_probs(sampler.proposal(), comp, &balls, &mut probs_native);
        xla.accept_probs(sampler.proposal(), comp, &balls, &mut probs_xla);
        assert_eq!(probs_native.len(), probs_xla.len());
        for (i, (&a, &b)) in probs_native.iter().zip(&probs_xla).enumerate() {
            let err = (a - b).abs();
            assert!(
                err < 1e-4 * a.max(1.0).max(b),
                "{} pair#{i} ({}, {}): native {a} xla {b}",
                comp.label(),
                balls.rows[i],
                balls.cols[i]
            );
        }
    }
    assert!(xla.pairs_scored >= 8000);
    assert!(xla.dispatches >= 4);
}

#[test]
fn xla_sampled_graph_statistically_matches_native() {
    let params = MagmParams::replicated(InitiatorMatrix::THETA2, 8, 0.45, 1 << 8);
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let assignment = params.sample_attributes(&mut rng);
    let sampler = MagmBdpSampler::new(&params, &assignment);
    let index = ColorIndex::build(&params, &assignment);
    let mut backend = XlaAccept::new(&params, &index).expect("XlaAccept");
    let batch = backend.batch_capacity();

    let reps = 15;
    let mean_native: f64 = (0..reps)
        .map(|_| sampler.sample_counted(&mut rng).0.num_edges() as f64)
        .sum::<f64>()
        / reps as f64;
    let mean_xla: f64 = (0..reps)
        .map(|_| {
            sampler
                .sample_batched(&mut rng, &mut backend, batch)
                .0
                .num_edges() as f64
        })
        .sum::<f64>()
        / reps as f64;
    let se = (mean_native.max(1.0) / reps as f64).sqrt();
    assert!(
        (mean_native - mean_xla).abs() < 8.0 * se,
        "native {mean_native} vs xla {mean_xla}"
    );
}

#[test]
fn runtime_rejects_oversized_models() {
    let params = MagmParams::replicated(InitiatorMatrix::THETA1, 22, 0.5, 1 << 22);
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    // Building a full assignment for 2^22 nodes is slow; use a small fake
    // one to hit the capacity check (accept artifact supports d ≤ 20).
    let small = MagmParams::replicated(InitiatorMatrix::THETA1, 22, 0.5, 64);
    let a = small.sample_attributes(&mut rng);
    let idx = ColorIndex::build(&small, &a);
    let err = XlaAccept::new(&params, &idx);
    assert!(err.is_err(), "d=22 must exceed the accept artifact's n_max");
}
